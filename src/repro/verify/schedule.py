"""Layer 3 — schedule sanitizer (``sch.*`` rules).

Symbolically replays ``Schedule.ops`` over the same versioned-region state
machine the scheduler itself uses (``SchedulerState`` semantics, reusing
``_bounds_overlap``), checking every op against the residency the stream has
actually established:

  * a ``copy``/``writeback`` whose source does not hold the region
    (``sch.operand-missing``) or holds a stale version (``sch.stale-read`` /
    ``sch.stale-writeback``),
  * a ``compute`` whose read operand is not resident at its device memory in
    the latest version (RAW hazard),
  * a write that overlaps an unreconciled dirty region of another
    granularity (WAW/WAR hazard, ``sch.overlap-dirty``),
  * final outputs not home in the latest version, and ``final_residency``
    entries the replay disagrees with,
  * per-compute-tile operand working sets vs the device memory capacity
    (``sch.capacity``) and the approach's VMEM budget (``sch.vmem-budget``).

The replay is *optimistic about eviction*: the scheduler drops clean LRU
copies without emitting ops, so the replay never forgets a copy it has seen.
That can only under-report residency hazards on evicted copies — it can
never flag a correct schedule (no false positives), which is the property
the mutation harness + golden suites pin down.
"""
from __future__ import annotations

from collections import Counter

from ..core.scheduler import Region, Schedule, _bounds_overlap
from .diagnostics import Diagnostic, diag


class _Replay:
    """Versioned-copy state mirroring ``SchedulerState`` (minus eviction)."""

    def __init__(self, sched: Schedule):
        self.sched = sched
        self.prog = sched.program
        self.homes = sched.homes
        self.latest: dict[tuple, int] = {}
        self.copies: dict[tuple, dict[str, int]] = {}
        self._dtypes = {b.name: b.dtype for b in self.prog.buffers}

    @staticmethod
    def key(region: Region) -> tuple:
        return (region.buffer, region.bounds)

    def nbytes(self, region: Region) -> int:
        return region.nbytes(self._dtypes.get(region.buffer, "f32"))

    def held_version(self, node: str, region: Region) -> int | None:
        """Version of ``region`` held at ``node``.

        The home memory implicitly holds v0 until a writeback commits a
        newer version there — that is physically true (the base data sits
        in the home buffer), so a read from home after uncommitted writes
        is a *stale* read, not a missing operand."""
        k = self.key(region)
        v = self.copies.get(k, {}).get(node)
        if v is None and self.homes.get(region.buffer) == node:
            return 0
        return v

    def install(self, node: str, region: Region, version: int):
        self.copies.setdefault(self.key(region), {})[node] = version

    def write(self, node: str, region: Region):
        """Mirror ``SchedulerState.install(dirty=True)`` + overlap invalidation.

        Unlike the scheduler, other nodes' same-key entries are *kept* at
        their old versions: the scheduler drops those copies, but every read
        it serves is preceded by an in-stream install of the latest version,
        so remembering the stale ones cannot flag a correct schedule — it
        only lets a mutated stream report ``sch.stale-read`` (version N vs
        latest M) instead of the less precise ``sch.operand-missing``."""
        k = self.key(region)
        v = self.latest.get(k, 0) + 1
        self.latest[k] = v
        self.copies.setdefault(k, {})[node] = v
        home = self.homes.get(region.buffer)
        for k2 in list(self.copies):
            if k2 == k or k2[0] != region.buffer:
                continue
            if not _bounds_overlap(k2[1], region.bounds):
                continue
            held = self.copies[k2]
            for n in list(held):
                if n != home:
                    held.pop(n)

    def overlapping_dirty(self, region: Region) -> list[tuple]:
        """Intersecting other-granularity keys with uncommitted writes."""
        k = self.key(region)
        home = self.homes.get(region.buffer)
        out = []
        for k2, held in self.copies.items():
            if k2 == k or k2[0] != region.buffer:
                continue
            v2 = self.latest.get(k2, 0)
            if v2 == 0 or held.get(home) == v2:
                continue
            if _bounds_overlap(k2[1], region.bounds):
                out.append(k2)
        return out


def verify_schedule(sched: Schedule, approach=None) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    rp = _Replay(sched)

    for op in sched.ops:
        if op.kind in ("copy", "writeback"):
            diags.extend(_check_move(rp, op))
        elif op.kind == "compute":
            diags.extend(_check_compute(rp, op, approach))
        else:
            diags.append(diag(
                "sch.unknown-node", f"op {op.uid} has unknown kind "
                f"{op.kind!r}", subject=op.kind, uid=op.uid))

    diags.extend(_check_final_state(rp))
    return diags


def verify_reschedule(sched: Schedule, selection, approach,
                      graph=None) -> list[Diagnostic]:
    """Check a schedule's compute tiles against what ``approach`` resolves
    for ``selection`` from scratch (``sch.tile-mismatch``).

    This closes the one hole incremental re-scheduling opens that the
    replay above cannot see: a stale-stream splice — a resumed schedule
    that kept a parent's ops for an instruction whose tile changed — is
    *self-consistent* (every copy precedes its read, every version chain
    checks out), it just computes the wrong tiling.  Only recomputing the
    expected per-instruction tile multiset can flag it.  Comparison is by
    multiset of (offsets, sizes) per ``instr_idx``, so it is independent of
    unroll order and of which device each tile landed on."""
    g = graph if graph is not None else sched.graph
    from ..core.scheduler import Scheduler

    def tkey(t) -> tuple:
        return (tuple(sorted(t.offsets.items())),
                tuple(sorted(t.sizes.items())))

    try:
        sch = Scheduler(selection, g, approach)
        expected: dict[int, Counter] = {}
        for idx, si in enumerate(selection.instrs):
            devices = g.compute_nodes_for(si.needle.name)
            if not devices:
                return []    # unschedulable selection: nothing to compare
            expected[idx] = Counter(
                tkey(t) for t in
                sch._tiles_for(idx, si, devices[0].matmul_tile))
    except Exception:
        return []            # expectation not computable — not this rule
    got: dict[int, Counter] = {idx: Counter() for idx in expected}
    for op in sched.ops:
        if op.kind != "compute" or op.tile is None:
            continue
        got.setdefault(op.tile.instr_idx, Counter())[tkey(op.tile)] += 1

    diags: list[Diagnostic] = []
    for idx in sorted(got):
        e = expected.get(idx)
        if e is None:
            diags.append(diag(
                "sch.tile-mismatch",
                f"compute ops reference instruction {idx}, which the "
                f"selection does not have", subject=str(idx)))
            continue
        if e != got[idx]:
            missing = sum((e - got[idx]).values())
            extra = sum((got[idx] - e).values())
            diags.append(diag(
                "sch.tile-mismatch",
                f"instruction {idx}: schedule's compute tiles do not match "
                f"the approach's resolved tiling ({missing} expected "
                f"tile(s) missing, {extra} unexpected — stale incremental "
                f"reuse?)", subject=str(idx)))
    return diags


def _check_move(rp: _Replay, op) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    g = rp.sched.graph
    region = op.region
    k = rp.key(region)
    for node in (op.src, op.dst):
        if node not in g.memories:
            diags.append(diag(
                "sch.unknown-node",
                f"{op.kind} {op.uid} references unknown memory node "
                f"{node!r}", subject=node, uid=op.uid))
            return diags
    try:
        g.edge(op.src, op.dst)
    except KeyError:
        diags.append(diag(
            "sch.unknown-node",
            f"{op.kind} {op.uid} moves {region.buffer} over nonexistent "
            f"edge {op.src}->{op.dst}", subject=op.src, uid=op.uid))
    if region.buffer not in rp.homes:
        diags.append(diag(
            "sch.unknown-node",
            f"{op.kind} {op.uid}: no home memory recorded for buffer "
            f"{region.buffer!r}", subject=region.buffer, uid=op.uid))
        return diags

    latest = rp.latest.get(k, 0)
    held = rp.held_version(op.src, region)
    if held is None:
        diags.append(diag(
            "sch.operand-missing",
            f"{op.kind} {op.uid} reads {region.buffer}{region.bounds} at "
            f"{op.src}, which holds no copy of it", subject=op.src,
            uid=op.uid))
    elif held != latest:
        rule = ("sch.stale-writeback" if op.kind == "writeback"
                else "sch.stale-read")
        diags.append(diag(
            rule,
            f"{op.kind} {op.uid} moves version {held} of "
            f"{region.buffer}{region.bounds} from {op.src} but latest is "
            f"{latest}", subject=op.src, uid=op.uid))
    # Install the latest version at dst regardless, so one corruption does
    # not cascade into a diagnostic per downstream consumer.
    rp.install(op.dst, region, latest)
    return diags


def _check_compute(rp: _Replay, op, approach) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    g = rp.sched.graph
    tile = op.tile
    dev = g.computes.get(op.device)
    if dev is None:
        diags.append(diag(
            "sch.unknown-node",
            f"compute {op.uid} runs on unknown device {op.device!r}",
            subject=op.device, uid=op.uid))
        return diags
    if not dev.executes(tile.needle_name):
        diags.append(diag(
            "sch.device-instr",
            f"compute {op.uid}: device {dev.name} does not execute "
            f"{tile.needle_name}", subject=dev.name, uid=op.uid))
    mem = dev.memory

    # Working set (distinct operand regions) must fit the device memory;
    # the scheduler pins exactly this set while the tile runs.
    distinct: dict[tuple, int] = {}
    for _, region, _, _ in tile.operands:
        distinct.setdefault(rp.key(region), rp.nbytes(region))
    working = sum(distinct.values())
    mnode = g.memories.get(mem)
    cap = mnode.capacity if mnode is not None else None
    # budget against whatever the target's compute-adjacent tier is called
    # (TPU VMEM, GPU shared memory, register files) — the memory *role*,
    # not a well-known node name.
    role = getattr(mnode, "role", "staging") if mnode is not None \
        else "staging"
    if cap is None:
        diags.append(diag(
            "sch.unknown-node",
            f"compute {op.uid}: device {dev.name} uses unknown memory "
            f"{mem!r}", subject=mem, uid=op.uid))
    elif working > cap:
        diags.append(diag(
            "sch.capacity",
            f"compute {op.uid} ({tile.needle_name}): operand working set "
            f"{working} bytes exceeds {role} memory {mem} capacity {cap}",
            subject=mem, uid=op.uid))
    elif approach is not None:
        frac = getattr(approach, "vmem_frac", 1.0)
        if 0.0 < frac < 1.0 and working > cap * frac:
            diags.append(diag(
                "sch.vmem-budget",
                f"compute {op.uid} ({tile.needle_name}): working set "
                f"{working} bytes exceeds vmem_frac {frac} of {role} "
                f"memory {mem} capacity {cap}", severity="warning",
                subject=mem, uid=op.uid))

    for _, region, r, w in tile.operands:
        if region.buffer not in rp.homes:
            diags.append(diag(
                "sch.unknown-node",
                f"compute {op.uid}: no home memory recorded for buffer "
                f"{region.buffer!r}", subject=region.buffer, uid=op.uid))
            continue
        k = rp.key(region)
        latest = rp.latest.get(k, 0)
        if r:
            held = rp.held_version(mem, region)
            if held is None:
                diags.append(diag(
                    "sch.operand-missing",
                    f"compute {op.uid} ({tile.needle_name}) reads "
                    f"{region.buffer}{region.bounds} at {mem}, which holds "
                    f"no copy of it (RAW hazard)", subject=mem, uid=op.uid))
            elif held != latest:
                diags.append(diag(
                    "sch.stale-read",
                    f"compute {op.uid} ({tile.needle_name}) reads version "
                    f"{held} of {region.buffer}{region.bounds} at {mem} "
                    f"but latest is {latest} (RAW hazard)",
                    subject=mem, uid=op.uid))
            rp.install(mem, region, latest)   # de-cascade
        else:
            # write-only operands are installed in place by the scheduler
            rp.install(mem, region, latest)
        if w:
            for k2 in rp.overlapping_dirty(region):
                diags.append(diag(
                    "sch.overlap-dirty",
                    f"compute {op.uid} writes {region.buffer}"
                    f"{region.bounds} while overlapping dirty region "
                    f"{k2[1]} was never reconciled home (WAW/WAR hazard)",
                    subject=region.buffer, uid=op.uid))
    for _, region, r, w in tile.operands:
        if w and region.buffer in rp.homes:
            rp.write(mem, region)
    return diags


def _check_final_state(rp: _Replay) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    sched = rp.sched

    # final_residency must agree with the replayed state (it may be a
    # subset: clean LRU evictions drop entries without emitting ops).
    for k, held in sched.final_residency.items():
        for node, ver in held.items():
            got = rp.copies.get(k, {}).get(node)
            if got is None and ver == 0 and rp.homes.get(k[0]) == node:
                got = 0
            if got != ver:
                diags.append(diag(
                    "sch.residency",
                    f"final_residency claims {node} holds version {ver} of "
                    f"{k[0]}{k[1]}, but the op stream leaves "
                    f"{'no copy' if got is None else f'version {got}'} "
                    f"there", subject=node))

    # every written output region must end at its home in the latest version
    outputs = set(sched.program.outputs)
    for k, v in rp.latest.items():
        buf = k[0]
        if buf not in outputs or v == 0:
            continue
        home = rp.homes.get(buf)
        if home is None:
            continue
        if rp.copies.get(k, {}).get(home) != v:
            diags.append(diag(
                "sch.output-not-home",
                f"output region {buf}{k[1]} ends at version {v} but home "
                f"{home} holds "
                f"{rp.copies.get(k, {}).get(home, 'no copy')}",
                subject=buf))
    return diags
