"""Layer 5 — cached-artifact payload checks (``art.*`` rules).

Structural validation of serialized ``CompiledKernel`` dicts before the
artifact cache hydrates them: schema/fields present, tile plans positive
and role-consistent with their ``axis_map``, cost finite and non-negative,
op counts non-negative ints.  Works on the raw JSON dict (no compile-layer
imports) so ``compile.cache`` can call it without an import cycle.
"""
from __future__ import annotations

import math

from .diagnostics import Diagnostic, diag

_REQUIRED = ("key", "cost", "instrs")


def verify_artifact_dict(d: dict) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    if not isinstance(d, dict):
        return [diag("art.schema", f"artifact payload is {type(d).__name__}, "
                     f"not a dict")]
    for fld in _REQUIRED:
        if fld not in d:
            diags.append(diag(
                "art.schema", f"artifact payload missing field {fld!r}",
                subject=fld))
    if diags:
        return diags

    cost = d.get("cost")
    if not isinstance(cost, (int, float)) or not math.isfinite(cost) \
            or cost < 0:
        diags.append(diag(
            "art.cost", f"artifact cost {cost!r} is not a finite "
            f"non-negative number", subject=str(d.get("key", ""))))

    for k, v in (d.get("counts") or {}).items():
        if not isinstance(v, int) or v < 0:
            diags.append(diag(
                "art.counts", f"op count {k!r} = {v!r} is not a "
                f"non-negative int", subject=str(k)))
    bm = d.get("bytes_moved", 0)
    if not isinstance(bm, int) or bm < 0:
        diags.append(diag(
            "art.counts", f"bytes_moved {bm!r} is not a non-negative int",
            subject="bytes_moved"))

    # Lowering configs are target-family-specific: a gpu-shaped config on a
    # tpu/paper artifact (or the reverse) means keys got crossed somewhere
    # upstream — exactly the corruption a shared cache file would show.
    lowering = d.get("lowering") or {}
    kind = lowering.get("kind", "") if isinstance(lowering, dict) else ""
    gname = str(d.get("graph_name", ""))
    gpu_graph = gname.startswith("gpu")
    if kind == "pallas_gpu_gemm" and gname and not gpu_graph:
        diags.append(diag(
            "art.lowering-target",
            f"gpu lowering config {kind!r} on non-gpu graph {gname!r}",
            subject=gname))
    elif kind == "pallas_gemm" and gpu_graph:
        diags.append(diag(
            "art.lowering-target",
            f"tpu lowering config {kind!r} on gpu graph {gname!r}",
            subject=gname))
    if kind == "pallas_gpu_gemm":
        smem = lowering.get("smem_bytes")
        if not isinstance(smem, int) or smem < 1:
            diags.append(diag(
                "art.lowering-target",
                f"gpu lowering config must carry positive smem_bytes, got "
                f"{smem!r}", subject=gname))

    for i, p in enumerate(d.get("instrs") or ()):
        if not isinstance(p, dict) or "needle" not in p:
            diags.append(diag(
                "art.instr-plan", f"instr plan {i} is malformed "
                f"(missing needle)", uid=i))
            continue
        roles = [a for a, _ in p.get("axis_map", [])]
        for axis, size in p.get("tile", []):
            if axis not in roles:
                diags.append(diag(
                    "art.instr-plan",
                    f"instr plan {i} ({p['needle']}): tile axis {axis!r} "
                    f"is not a mapped role {roles}", subject=p["needle"],
                    uid=i))
            if not isinstance(size, int) or size < 1:
                diags.append(diag(
                    "art.instr-plan",
                    f"instr plan {i} ({p['needle']}): tile size {size!r} "
                    f"for axis {axis!r} must be a positive int",
                    subject=p["needle"], uid=i))
        calls = p.get("calls", 1)
        if not isinstance(calls, int) or calls < 1:
            diags.append(diag(
                "art.instr-plan",
                f"instr plan {i} ({p['needle']}): calls {calls!r} must be "
                f"a positive int", subject=p["needle"], uid=i))
    return diags
