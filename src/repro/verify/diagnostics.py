"""Structured diagnostics — the currency of the ``repro.verify`` analyzer.

Every verifier layer (program / selection / schedule / fabric / artifact)
emits ``Diagnostic`` records instead of raising bare exceptions: a stable
*rule id*, a severity, the offending object (a ``ScheduledOp.uid``, a
statement index, a buffer or node name) and a human message.  A
``DiagnosticReport`` aggregates them per verification run; ``ok`` means *no
error-severity findings* (warnings surface but do not fail a compile).

Rule ids are namespaced by layer (``prg.*``, ``sel.*``, ``sch.*``,
``fab.*``, ``gra.*``, ``srv.*``, ``art.*``) and registered in ``RULES`` so
the CLI, the mutation harness and the README rule table all speak from one
source.
"""
from __future__ import annotations

from dataclasses import dataclass, field

ERROR = "error"
WARNING = "warning"

#: rule id -> one-line description (the README table renders this).
RULES: dict[str, str] = {
    # program verifier (verify/program.py)
    "prg.rank": "access rank must match the buffer's rank",
    "prg.axis": "access matrix width must match the declared axis count",
    "prg.bounds": "affine accesses must stay in-bounds under axis extents",
    "prg.temp-read": "temp buffers must be written before they are read",
    "prg.output-unwritten": "every declared output must be written",
    "prg.unknown-buffer": "accesses must name declared buffers",
    "prg.dtype": "buffer dtypes must be known to core/dtypes.py",
    # selection verifier (verify/selection.py)
    "sel.coverage-gap": "every statement must be covered by an instruction",
    "sel.coverage-overlap": "no statement may be covered twice",
    "sel.axis-role": "axis_map must be injective over existing axes",
    "sel.buffer-map": "buffer_map must bind existing needle/haystack buffers",
    "sel.tile-cap": "tile caps must be positive and vmem_frac in (0, 1]",
    # schedule sanitizer (verify/schedule.py)
    "sch.unknown-node": "ops must reference nodes present in the SystemGraph",
    "sch.device-instr": "a compute op's device must execute its needle",
    "sch.operand-missing": "a compute/copy reads a region not resident at "
                           "its source in any version (RAW hazard)",
    "sch.stale-read": "a compute/copy reads an out-of-date version of a "
                      "region (RAW hazard)",
    "sch.overlap-dirty": "a write overlaps an unreconciled dirty region "
                         "(WAW/WAR hazard)",
    "sch.stale-writeback": "a writeback carries a version older than the "
                           "latest",
    "sch.capacity": "a tile's operand working set must fit its device "
                    "memory",
    "sch.vmem-budget": "a tile's working set exceeds the approach's "
                       "staging-memory budget (vmem_frac)",
    "sch.output-not-home": "final output regions must reside at their home "
                           "memory in the latest version",
    "sch.residency": "final_residency must agree with the replayed state",
    "sch.tile-mismatch": "a schedule's per-instruction compute tiles must "
                         "match what the approach resolves for the "
                         "selection (stale incremental reuse)",
    # fabric checker (verify/fabric.py)
    "fab.cycle": "collective/task dependency graphs must be acyclic",
    "fab.unknown-dep": "tasks must depend only on known tasks",
    "fab.duplicate-task": "task ids must be unique",
    "fab.unreachable": "every chip must receive every chunk it is owed",
    "fab.chain-broken": "reduce chains must visit all chips exactly once",
    "fab.contract": "per-chip shards must satisfy the sharded-output "
                    "contract",
    # graph verifier (verify/graph.py)
    "gra.unknown-tensor": "node wiring must reference declared tensors and "
                          "program buffers",
    "gra.shape": "a wired tensor's shape must match its program buffer",
    "gra.dtype": "a wired tensor's dtype must match its program buffer",
    "gra.cycle": "nodes must only consume tensors produced earlier "
                 "(acyclic, topologically ordered)",
    "gra.duplicate-producer": "every tensor must have at most one producer",
    "gra.output": "graph outputs must be produced and wired output buffers "
                  "must be program outputs",
    "gra.node-program": "every node's kernel program must verify clean "
                        "(prg.* layer)",
    "gra.capacity": "vmem-resident live tensors must fit the placement "
                    "budget",
    # serving-trace checker (verify/serve.py)
    "srv.kv-budget": "admitted batches must respect the KV byte budget and "
                     "the batch cap",
    "srv.bucket-route": "every request must be served by its pad-up "
                        "lattice bucket",
    "srv.replay-drift": "a frozen schedule must replay to identical "
                        "per-request admit/completion times",
    "srv.starvation": "every arrived request must eventually be admitted "
                      "and complete",
    # artifact payload checks (cached loads, verify/artifact.py)
    "art.schema": "artifact payloads must carry the known schema/fields",
    "art.instr-plan": "tile plans must be role-consistent and positive",
    "art.cost": "artifact cost must be a finite non-negative number",
    "art.counts": "op counts must be non-negative integers",
    "art.lowering-target": "the lowering config must match the artifact's "
                           "target family (no gpu lowering on a tpu graph "
                           "or vice versa)",
}


@dataclass(frozen=True)
class Diagnostic:
    """One finding: rule id + severity + offending object + message."""

    rule: str
    message: str
    severity: str = ERROR
    layer: str = ""                 # prg | sel | sch | fab | art
    subject: str = ""               # buffer/axis/node/needle name
    uid: int | None = None          # ScheduledOp.uid or statement index

    def __post_init__(self):
        if not self.layer:
            object.__setattr__(self, "layer", self.rule.split(".", 1)[0])

    def to_dict(self) -> dict:
        d = {"rule": self.rule, "severity": self.severity,
             "layer": self.layer, "message": self.message}
        if self.subject:
            d["subject"] = self.subject
        if self.uid is not None:
            d["uid"] = self.uid
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Diagnostic":
        return cls(rule=d["rule"], message=d.get("message", ""),
                   severity=d.get("severity", ERROR),
                   layer=d.get("layer", ""), subject=d.get("subject", ""),
                   uid=d.get("uid"))

    def __str__(self) -> str:
        loc = f" @{self.subject}" if self.subject else ""
        if self.uid is not None:
            loc += f" uid={self.uid}"
        return f"[{self.severity}] {self.rule}{loc}: {self.message}"


@dataclass
class DiagnosticReport:
    """All findings of one verification run."""

    diagnostics: list[Diagnostic] = field(default_factory=list)
    meta: dict = field(default_factory=dict)

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == ERROR]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def rules(self) -> list[str]:
        return [d.rule for d in self.diagnostics]

    def extend(self, diags) -> "DiagnosticReport":
        self.diagnostics.extend(diags)
        return self

    def to_dict(self) -> dict:
        return {"ok": self.ok,
                "errors": len(self.errors),
                "warnings": len(self.warnings),
                "diagnostics": [d.to_dict() for d in self.diagnostics],
                "meta": dict(self.meta)}

    @classmethod
    def from_dict(cls, d: dict) -> "DiagnosticReport":
        return cls(diagnostics=[Diagnostic.from_dict(x)
                                for x in d.get("diagnostics", [])],
                   meta=dict(d.get("meta", {})))

    def render(self, limit: int = 20) -> str:
        if not self.diagnostics:
            return "clean (0 diagnostics)"
        lines = [str(d) for d in self.diagnostics[:limit]]
        if len(self.diagnostics) > limit:
            lines.append(f"... and {len(self.diagnostics) - limit} more")
        return "\n".join(lines)


class VerifyError(RuntimeError):
    """Raised by strict verification entry points (``VerifyPass``)."""

    def __init__(self, report: DiagnosticReport, context: str = ""):
        self.report = report
        head = f"verification failed ({len(report.errors)} error(s))"
        if context:
            head += f" for {context}"
        super().__init__(head + ":\n" + report.render())


def diag(rule: str, message: str, *, severity: str = ERROR,
         subject: str = "", uid: int | None = None) -> Diagnostic:
    """Shorthand constructor that validates the rule id against ``RULES``."""
    if rule not in RULES:
        raise KeyError(f"unregistered verify rule {rule!r}")
    return Diagnostic(rule=rule, message=message, severity=severity,
                      subject=subject, uid=uid)
