"""Serving-trace checker (``srv.*``) — the analyzer layer for
``repro.serve``.

A serve run serializes to a trace dict (``ServeResult.trace()``: request
records, per-iteration batches, params).  These checks replay the
*invariants* the online scheduler must have respected, independently of
the simulator that produced the trace — so a mutated/corrupted trace (or
a buggy scheduler) is caught from the artifact alone:

  * ``srv.kv-budget``   — per iteration, the running batch's KV bytes fit
    the budget and the batch cap;
  * ``srv.bucket-route``— every request sits in its pad-up lattice bucket;
  * ``srv.starvation``  — every request was admitted and completed;
  * ``srv.replay-drift``— (``verify_replay``) two traces of the same
    workload — e.g. an online run vs its frozen static replay — agree on
    every request's admit and completion time.
"""
from __future__ import annotations

from .diagnostics import Diagnostic, diag


def _requests(trace: dict) -> dict[int, dict]:
    return {int(r["rid"]): r for r in trace.get("requests", [])}


def verify_serve_trace(trace: dict) -> list[Diagnostic]:
    """Check one serve-run trace against the admission invariants."""
    diags: list[Diagnostic] = []
    reqs = _requests(trace)
    params = trace.get("params", {})
    kv_budget = int(params.get("kv_budget", 0))
    max_batch = int(params.get("max_batch", 0))
    buckets = sorted(int(b) for b in trace.get("buckets", []))

    # admission control: KV bytes + batch cap, per iteration
    for itrec in trace.get("iterations", []):
        running = [int(r) for r in itrec.get("running", [])]
        kv = sum(int(reqs[r]["kv_bytes"]) for r in running if r in reqs)
        if kv_budget and kv > kv_budget:
            diags.append(diag(
                "srv.kv-budget",
                f"iteration {itrec.get('i')} holds {kv} KV bytes over the "
                f"{kv_budget}-byte budget", subject=f"iter:{itrec.get('i')}"))
        if max_batch and len(running) > max_batch:
            diags.append(diag(
                "srv.kv-budget",
                f"iteration {itrec.get('i')} runs {len(running)} requests "
                f"over the batch cap {max_batch}",
                subject=f"iter:{itrec.get('i')}"))

    # bucket routing: pad-up to the smallest fitting lattice bucket
    for rid, r in sorted(reqs.items()):
        want = next((b for b in buckets if int(r["prompt_len"]) <= b), None)
        if want is None or int(r["bucket"]) != want:
            diags.append(diag(
                "srv.bucket-route",
                f"request {rid} (prompt {r['prompt_len']}) served at bucket "
                f"{r['bucket']}, expected {want}", subject=f"req:{rid}"))

    # liveness: every request admitted and completed
    for rid, r in sorted(reqs.items()):
        if r.get("admitted") is None or r.get("completed") is None:
            stage = "admitted" if r.get("admitted") is None else "completed"
            diags.append(diag(
                "srv.starvation",
                f"request {rid} was never {stage}", subject=f"req:{rid}"))
    return diags


def verify_replay(frozen: dict, online: dict) -> list[Diagnostic]:
    """Check a frozen-schedule replay against its originating online run:
    same requests, bit-identical admit and completion times."""
    diags: list[Diagnostic] = []
    fr, on = _requests(frozen), _requests(online)
    if set(fr) != set(on):
        missing = sorted(set(on) ^ set(fr))
        diags.append(diag(
            "srv.replay-drift",
            f"replay serves a different request set (mismatch: {missing})"))
        return diags
    for rid in sorted(fr):
        for field in ("admitted", "completed"):
            a, b = fr[rid].get(field), on[rid].get(field)
            if a != b:
                diags.append(diag(
                    "srv.replay-drift",
                    f"request {rid} {field} drifts: frozen={a} online={b}",
                    subject=f"req:{rid}"))
    return diags
