"""Graph-layer verifier (``gra.*``) — the tolerant, diagnostic-emitting
twin of ``repro.graph.ir.KernelGraph.validate``.

``validate()`` raises on first violation (the constructor fast path);
``verify_graph`` keeps going and reports *every* finding, duck-typing the
graph so corrupted objects (bad serialization, a buggy pass, the mutation
harness's ``object.__setattr__`` edits) cannot crash the analyzer before
it has had its say.  ``verify_placement`` replays the liveness walk of
``repro.graph.compile.plan_placement`` against any claimed placement and
budget — the graph-tier capacity rule, analogous to ``sch.capacity`` one
layer down.
"""
from __future__ import annotations

from .diagnostics import Diagnostic, diag
from .program import verify_program


def verify_graph(g) -> list[Diagnostic]:
    """Structural checks on a ``KernelGraph``: wiring, shapes/dtypes,
    topological order, producer uniqueness, output coverage, and a
    ``prg.*`` sweep over every node's kernel program (summarized as
    ``gra.node-program`` so one graph finding names the offending node)."""
    diags: list[Diagnostic] = []
    known = set(g.tensors)
    for t in list(g.inputs) + list(g.outputs):
        if t not in known:
            diags.append(diag("gra.unknown-tensor",
                              f"graph boundary names unknown tensor {t!r}",
                              subject=t))
    produced: set[str] = set(g.inputs)
    producers: dict[str, str] = {}
    names: set[str] = set()
    for node in g.nodes:
        if node.name in names:
            diags.append(diag("gra.duplicate-producer",
                              f"duplicate node name {node.name!r}",
                              subject=node.name))
        names.add(node.name)
        for buf, t in tuple(node.inputs) + tuple(node.outputs):
            if t not in known:
                diags.append(diag("gra.unknown-tensor",
                                  f"{node.name}: wires unknown tensor {t!r}",
                                  subject=node.name))
                continue
            try:
                b = node.program.buffer(buf)
            except KeyError:
                diags.append(diag("gra.unknown-tensor",
                                  f"{node.name}: wires unknown buffer "
                                  f"{buf!r}", subject=node.name))
                continue
            spec = g.tensors[t]
            if tuple(b.shape) != tuple(spec.shape):
                diags.append(diag("gra.shape",
                                  f"{node.name}: buffer {buf} shape "
                                  f"{tuple(b.shape)} != tensor {t} shape "
                                  f"{tuple(spec.shape)}", subject=node.name))
            if b.dtype != spec.dtype:
                diags.append(diag("gra.dtype",
                                  f"{node.name}: buffer {buf} dtype "
                                  f"{b.dtype} != tensor {t} dtype "
                                  f"{spec.dtype}", subject=node.name))
        for _, t in node.inputs:
            if t in known and t not in produced:
                diags.append(diag("gra.cycle",
                                  f"{node.name}: consumes {t!r} before it "
                                  f"is produced (cycle or bad topological "
                                  f"order)", subject=node.name))
        for buf, t in node.outputs:
            if t in produced:
                diags.append(diag(
                    "gra.duplicate-producer",
                    f"{node.name}: tensor {t!r} already has a producer "
                    f"({producers.get(t, 'graph input')})", subject=t))
            if buf not in node.program.outputs:
                diags.append(diag("gra.output",
                                  f"{node.name}: wired output buffer "
                                  f"{buf!r} is not a program output",
                                  subject=node.name))
            produced.add(t)
            producers[t] = node.name
        prg = [d for d in verify_program(node.program)
               if d.severity == "error"]
        if prg:
            rules = sorted({d.rule for d in prg})
            diags.append(diag("gra.node-program",
                              f"{node.name}: program "
                              f"{node.program.name!r} fails "
                              f"{', '.join(rules)}", subject=node.name))
    for t in g.outputs:
        if t in known and t not in produced:
            diags.append(diag("gra.output",
                              f"graph output {t!r} is never produced",
                              subject=t))
    return diags


def verify_placement(g, locations: dict, budget: int) -> list[Diagnostic]:
    """Replay the liveness walk against a claimed placement: at no point may
    the VMEM-resident live set exceed ``budget``, and every intermediate
    must have a legal location."""
    diags: list[Diagnostic] = []
    inter = set(g.intermediates())
    for t in inter:
        loc = locations.get(t)
        if loc not in ("vmem", "hbm"):
            diags.append(diag("gra.capacity",
                              f"intermediate {t!r} has no legal placement "
                              f"(got {loc!r})", subject=t))
    last_use: dict[str, int] = {}
    for i, node in enumerate(g.nodes):
        for t in node.consumed():
            if t in inter:
                last_use[t] = i
    resident: dict[str, int] = {}
    used = 0
    for i, node in enumerate(g.nodes):
        for t in node.produced():
            if t in inter and locations.get(t) == "vmem":
                nb = g.tensors[t].nbytes
                resident[t] = nb
                used += nb
                if used > budget:
                    diags.append(diag(
                        "gra.capacity",
                        f"at node {node.name}: resident set {used}B "
                        f"exceeds budget {budget}B placing {t!r}",
                        subject=node.name))
        for t in [t for t, li in last_use.items()
                  if li <= i and t in resident]:
            used -= resident.pop(t)
    return diags
