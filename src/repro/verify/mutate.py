"""Mutation harness — proof that the static analyzer has teeth.

Each registered mutation takes a *known-good* compile bundle (program +
selection + schedule off a real ``compile_*`` run, or a real fabric
partition/collective plan), corrupts it in one specific way, re-runs the
verifier stack and reports which rules fired.  ``run_all`` asserts two
properties the test-suite pins down:

  * every corruption class is **caught**, with the expected rule id among
    the findings (one mutation ~ one primary diagnostic), and
  * the **unmutated** bundles verify clean (zero false positives).

Mutations bypass the IR constructors on purpose (``object.__setattr__`` on
frozen dataclasses): real corruption — bad serialization, a buggy pass, a
hand-edited cache — does not politely call ``__post_init__``.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field, replace

from ..core.scheduler import Region
from .diagnostics import RULES, Diagnostic, DiagnosticReport
from .program import verify_program
from .schedule import verify_reschedule, verify_schedule
from .selection import verify_selection

# --------------------------------------------------------------------------- #
# Bundles: one real compile / partition per workload, deep-copied per mutation
# --------------------------------------------------------------------------- #


@dataclass
class Bundle:
    """Everything one mutation may corrupt (a deep copy per run)."""

    program: object = None
    selection: object = None
    schedule: object = None
    approach: object = None
    artifact: dict | None = None          # serialized CompiledKernel payload
    partition: object = None              # fabric PartitionedProgram
    topo: object = None
    steps: dict = field(default_factory=dict)   # collective kind -> steps
    tasks: list = field(default_factory=list)   # EventSim (tid, deps) pairs
    kgraph: object = None                 # repro.graph KernelGraph
    locations: dict = field(default_factory=dict)  # tensor -> vmem|hbm
    budget: int = 0
    trace: dict = field(default_factory=dict)   # repro.serve run trace
    trace2: dict = field(default_factory=dict)  # its bit-identical twin
    sysgraph: object = None               # SystemGraph (incremental kind)
    parent_schedule: object = None        # anchor schedule to resume from
    segments: dict = field(default_factory=dict)  # idx -> (op count, state)
    first_changed: int = 0                # first instr whose tile differs


_BASE: dict[str, Bundle] = {}


def _gemm_bundle() -> Bundle:
    if "gemm" not in _BASE:
        from ..compile.driver import compile_gemm
        art = compile_gemm(64, 32, 48, use_cache=False)
        _BASE["gemm"] = Bundle(program=art.selection.program,
                               selection=art.selection,
                               schedule=art.ensure_schedule(),
                               approach=art.approach,
                               artifact=art.to_dict())
    return copy.deepcopy(_BASE["gemm"])


def _gpu_bundle() -> Bundle:
    """A known-good compile on the GPU target: same program family as the
    gemm bundle, but scheduled against ``gpu_sm`` shared memory and lowered
    to the ``pallas_gpu_gemm`` config — the surface the two GPU corruption
    classes attack."""
    if "gpu" not in _BASE:
        from ..compile.driver import compile_gemm
        from ..core.sysgraph import gpu_sm
        art = compile_gemm(64, 32, 48, graph=gpu_sm(2), use_cache=False)
        _BASE["gpu"] = Bundle(program=art.selection.program,
                              selection=art.selection,
                              schedule=art.ensure_schedule(),
                              approach=art.approach,
                              artifact=art.to_dict())
    return copy.deepcopy(_BASE["gpu"])


def _fabric_bundle() -> Bundle:
    if "fabric" not in _BASE:
        from ..fabric.partition import partition
        from ..fabric.simulate import _lower, simulate_partition
        from ..fabric.topology import make_topology
        topo = make_topology("ring", 4)
        # n-partition lowers an all_gather, k-partition a reduce chain.
        pp = partition("gemm", (256, 128, 64), "n", topo.n_chips)
        ppk = partition("gemm", (256, 128, 64), "k", topo.n_chips)
        steps = {spec.kind: _lower(spec, pp, topo, "ring")
                 for spec in pp.collectives}
        steps.update({spec.kind: _lower(spec, ppk, topo, "ring")
                      for spec in ppk.collectives})
        sim_out: list = []
        simulate_partition(pp, topo, None, "ring", None, sim_out=sim_out)
        tasks = [(t.tid, tuple(t.deps)) for t in sim_out[0]._tasks]
        _BASE["fabric"] = Bundle(partition=pp, topo=topo, steps=steps,
                                 tasks=tasks)
    return copy.deepcopy(_BASE["fabric"])


def _graph_bundle() -> Bundle:
    if "graph" not in _BASE:
        from ..configs.registry import get_trace_config
        from ..graph.compile import plan_placement
        from ..graph.fuse import fuse_epilogues
        from ..graph.trace import trace_block
        g, _ = fuse_epilogues(
            trace_block(get_trace_config("olmo-1b"), seq_len=4))
        budget = 4096    # small enough that the plan mixes vmem and hbm
        pl = plan_placement(g, budget)
        _BASE["graph"] = Bundle(kgraph=g, locations=dict(pl.locations),
                                budget=budget)
    return copy.deepcopy(_BASE["graph"])


def _serve_bundle() -> Bundle:
    if "serve" not in _BASE:
        import copy as _copy
        from ..serve.bucket import ServingPool
        from ..serve.scheduler import FifoOnlineScheduler
        from ..serve.simulate import ServeParams, simulate_serving
        from ..serve.workload import generate_requests
        pool = ServingPool(archs=("olmo-1b",), buckets=(4, 8),
                           use_cache=False)
        pool.warmup()
        reqs = generate_requests(8, seed=3, rate=400.0,
                                 prompt_lens=(2, 4, 6, 8),
                                 decode_lens=(1, 2, 3))
        res = simulate_serving(reqs, pool, FifoOnlineScheduler(),
                               ServeParams(max_batch=4, kv_budget=1 << 15))
        trace = res.trace()
        _BASE["serve"] = Bundle(trace=trace, trace2=_copy.deepcopy(trace))
    return copy.deepcopy(_BASE["serve"])


def _incremental_bundle() -> Bundle:
    """A real incremental re-schedule: a heterogeneous GRU (input dim !=
    hidden dim) whose first matmul's reduction (k=64, below the hardware
    tile) is cap-invariant, so a ``tile_k`` change shares an unchanged
    instruction-0 prefix with the baseline anchor — ``first_changed`` is 1
    and the child schedule genuinely resumes mid-stream."""
    if "incremental" not in _BASE:
        from ..compile.driver import gru_selection
        from ..core.scheduler import (schedule_incremental,
                                      schedule_with_segments)
        from ..core.sysgraph import tpu_v5e
        from ..search.space import ParamApproach, SearchSpace
        graph = tpu_v5e(1)
        _, sel = gru_selection(4, 256, 64)
        base = SearchSpace.for_graph(graph).baseline()
        parent, segments = schedule_with_segments(sel, graph,
                                                  ParamApproach(base))
        child_ap = ParamApproach(dict(base, tile_k=128))
        child, _ = schedule_incremental(sel, graph, child_ap, parent,
                                        segments, 1)
        _BASE["incremental"] = Bundle(
            program=sel.program, selection=sel, schedule=child,
            approach=child_ap, sysgraph=graph, parent_schedule=parent,
            segments=segments, first_changed=1)
    return copy.deepcopy(_BASE["incremental"])


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #

#: name -> (expected rule, bundle kind, mutator).  The mutator corrupts the
#: bundle in place and may return a Diagnostic list of its own (fabric/art
#: classes verify sub-objects directly).
MUTATIONS: dict[str, tuple[str, str, object]] = {}


def mutation(name: str, rule: str, kind: str = "gemm"):
    if rule not in RULES:
        raise KeyError(f"unregistered verify rule {rule!r}")

    def register(fn):
        MUTATIONS[name] = (rule, kind, fn)
        return fn
    return register


def _verify_bundle(b: Bundle) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    if b.program is not None:
        diags.extend(verify_program(b.program))
    if b.selection is not None:
        diags.extend(verify_selection(b.selection, b.approach))
    if b.schedule is not None:
        diags.extend(verify_schedule(b.schedule, b.approach))
        if b.selection is not None and b.approach is not None:
            diags.extend(verify_reschedule(b.schedule, b.selection,
                                           b.approach))
    if b.kgraph is not None:
        from .graph import verify_graph, verify_placement
        diags.extend(verify_graph(b.kgraph))
        diags.extend(verify_placement(b.kgraph, b.locations, b.budget))
    if b.trace:
        from .serve import verify_replay, verify_serve_trace
        diags.extend(verify_serve_trace(b.trace))
        if b.trace2:
            diags.extend(verify_replay(b.trace, b.trace2))
    return diags


# -- program layer ---------------------------------------------------------- #


@mutation("prg-oob-access", "prg.bounds")
def _mut_oob_access(b: Bundle):
    s = b.program.statements[0]
    off = tuple(o + 10_000 for o in s.rhs.offset)
    object.__setattr__(s.rhs, "offset", off)


@mutation("prg-unknown-dtype", "prg.dtype")
def _mut_unknown_dtype(b: Bundle):
    object.__setattr__(b.program.buffers[0], "dtype", "q7")


@mutation("prg-temp-read", "prg.temp-read")
def _mut_temp_read(b: Bundle):
    # Reclassify a pure input as a temp: now it is read before any write.
    written = {s.lhs.buffer for s in b.program.statements}
    inp = next(bu for bu in b.program.buffers if bu.name not in written)
    object.__setattr__(inp, "temp", True)


@mutation("prg-output-unwritten", "prg.output-unwritten")
def _mut_output_unwritten(b: Bundle):
    written = {s.lhs.buffer for s in b.program.statements}
    inp = next(bu.name for bu in b.program.buffers if bu.name not in written)
    object.__setattr__(b.program, "outputs", b.program.outputs + (inp,))


@mutation("prg-unknown-buffer", "prg.unknown-buffer")
def _mut_unknown_buffer(b: Bundle):
    object.__setattr__(b.program, "outputs", b.program.outputs + ("GHOST",))


# -- selection layer -------------------------------------------------------- #


@mutation("sel-uncover", "sel.coverage-gap")
def _mut_uncover(b: Bundle):
    m = b.selection.instrs[0].mapping
    object.__setattr__(m, "stmt_map", tuple(m.stmt_map)[:-1])


@mutation("sel-double-cover", "sel.coverage-overlap")
def _mut_double_cover(b: Bundle):
    m = b.selection.instrs[0].mapping
    object.__setattr__(m, "stmt_map",
                       tuple(m.stmt_map) + (m.stmt_map[0],))


@mutation("sel-axis-role", "sel.axis-role")
def _mut_axis_role(b: Bundle):
    m = b.selection.instrs[0].mapping
    amap = list(m.axis_map)
    amap[1] = (amap[1][0], amap[0][1])        # two needle axes -> one haystack
    object.__setattr__(m, "axis_map", tuple(amap))


@mutation("sel-buffer-map", "sel.buffer-map")
def _mut_buffer_map(b: Bundle):
    m = b.selection.instrs[0].mapping
    bmap = list(m.buffer_map)
    bmap[0] = (bmap[0][0], "GHOST")
    object.__setattr__(m, "buffer_map", tuple(bmap))


@mutation("sel-tile-cap", "sel.tile-cap")
def _mut_tile_cap(b: Bundle):
    class _Bad:
        tile_caps = (0, None, None)
        vmem_frac = 1.5
    b.approach = _Bad()


# -- schedule layer --------------------------------------------------------- #


def _first_op(sched, kind: str, pred=lambda op: True):
    return next(op for op in sched.ops if op.kind == kind and pred(op))


@mutation("sch-drop-copy", "sch.operand-missing")
def _mut_drop_copy(b: Bundle):
    sched = b.schedule
    victim = _first_op(sched, "copy",
                       lambda op: op.region.buffer not in sched.program.outputs)
    sched.ops = [op for op in sched.ops if op.uid != victim.uid]


@mutation("sch-stale-read", "sch.stale-read")
def _mut_stale_read(b: Bundle):
    # Re-issue the initial home->device copy of an output region *after* the
    # device has produced newer versions: the copy now reads home's stale v0.
    sched = b.schedule
    outs = set(sched.program.outputs)
    cp = _first_op(sched, "copy", lambda op: op.region.buffer in outs)
    last_w = max(i for i, op in enumerate(sched.ops)
                 if op.kind == "compute" and any(
                     w and r2.buffer == cp.region.buffer
                     and r2.bounds == cp.region.bounds
                     for _, r2, _, w in op.tile.operands))
    sched.ops = (list(sched.ops[:last_w + 1]) + [replace(cp, uid=9_000)]
                 + list(sched.ops[last_w + 1:]))


@mutation("sch-stale-writeback", "sch.stale-writeback")
def _mut_stale_writeback(b: Bundle):
    # Reroute the final writeback to *source* from the home memory, which
    # still holds the stale v0 base data.
    sched = b.schedule
    wb = [op for op in sched.ops if op.kind == "writeback"][-1]
    home = sched.homes[wb.region.buffer]
    idx = sched.ops.index(wb)
    sched.ops[idx] = replace(wb, src=home, dst=wb.src)


@mutation("sch-swap-ops", "sch.operand-missing")
def _mut_swap_ops(b: Bundle):
    # Hoist a compute above the copies that stage its operands.
    sched = b.schedule
    first_compute = _first_op(sched, "compute")
    rest = [op for op in sched.ops if op.uid != first_compute.uid]
    sched.ops = [first_compute] + rest


@mutation("sch-shrink-region", "sch.operand-missing")
def _mut_shrink_region(b: Bundle):
    sched = b.schedule
    cp = _first_op(sched, "copy")
    (start, span), *tail = cp.region.bounds
    shrunk = Region(cp.region.buffer,
                    ((start, max(1, span // 2)), *tail))
    idx = sched.ops.index(cp)
    sched.ops[idx] = replace(cp, region=shrunk)


@mutation("sch-unknown-device", "sch.unknown-node")
def _mut_unknown_device(b: Bundle):
    sched = b.schedule
    op = _first_op(sched, "compute")
    idx = sched.ops.index(op)
    sched.ops[idx] = replace(op, device="warp9")


@mutation("sch-inflate-region", "sch.capacity")
def _mut_inflate_region(b: Bundle):
    # Balloon one compute operand past any device memory capacity.
    sched = b.schedule
    op = _first_op(sched, "compute")
    buf, region, r, w = op.tile.operands[0]
    huge = Region(region.buffer,
                  tuple((s, 1 << 16) for s, _ in region.bounds))
    op.tile.operands[0] = (buf, huge, r, w)


@mutation("sch-bump-version", "sch.residency")
def _mut_bump_version(b: Bundle):
    sched = b.schedule
    k = next(iter(sched.final_residency))
    held = sched.final_residency[k]
    node = next(iter(held))
    held[node] += 1


@mutation("sch-drop-writeback", "sch.output-not-home")
def _mut_drop_writeback(b: Bundle):
    sched = b.schedule
    wb = [op for op in sched.ops if op.kind == "writeback"][-1]
    sched.ops = [op for op in sched.ops if op.uid != wb.uid]
    sched.final_residency.pop((wb.region.buffer, wb.region.bounds), None)


# -- incremental re-scheduling ---------------------------------------------- #


@mutation("inc-stale-stream", "sch.tile-mismatch", kind="incremental")
def _mut_inc_stale_stream(b: Bundle):
    # Resume one instruction too late: the parent's op stream for the
    # instruction whose tile actually changed is kept verbatim.  The splice
    # is *self-consistent* — every copy precedes its read, every version
    # chain checks out — so the sch.* replay stays silent; only recomputing
    # the expected tiling (verify_reschedule) can flag the stale reuse.
    from ..core.scheduler import schedule_incremental
    bad, _ = schedule_incremental(b.selection, b.sysgraph, b.approach,
                                  b.parent_schedule, b.segments,
                                  b.first_changed + 1)
    return (verify_schedule(bad, b.approach)
            + verify_reschedule(bad, b.selection, b.approach, b.sysgraph))


@mutation("inc-wrong-instr", "sch.residency", kind="incremental")
def _mut_inc_wrong_instr(b: Bundle):
    # Apply the delta at the wrong op boundary: keep the resume *state* of
    # the changed instruction but truncate the parent prefix short of it —
    # ops whose effects the state already claims never appear in the
    # stream, so the replayed residency disagrees with final_residency.
    from ..core.scheduler import schedule_incremental
    boundary, snap = b.segments[b.first_changed - 1]
    bad_segments = dict(b.segments)
    bad_segments[b.first_changed - 1] = (max(0, boundary - 4), snap)
    bad, _ = schedule_incremental(b.selection, b.sysgraph, b.approach,
                                  b.parent_schedule, bad_segments,
                                  b.first_changed)
    return (verify_schedule(bad, b.approach)
            + verify_reschedule(bad, b.selection, b.approach, b.sysgraph))


# -- fabric layer ----------------------------------------------------------- #


@mutation("fab-cycle", "fab.cycle", kind="fabric")
def _mut_fab_cycle(b: Bundle):
    from .fabric import verify_task_graph
    tid0, deps0 = b.tasks[0]
    b.tasks[0] = (tid0, deps0 + (b.tasks[-1][0],))
    return verify_task_graph(b.tasks)


@mutation("fab-duplicate-task", "fab.duplicate-task", kind="fabric")
def _mut_fab_dup(b: Bundle):
    from .fabric import verify_task_graph
    b.tasks.append(b.tasks[0])
    return verify_task_graph(b.tasks)


@mutation("fab-unknown-dep", "fab.unknown-dep", kind="fabric")
def _mut_fab_unknown_dep(b: Bundle):
    from .fabric import verify_task_graph
    tid0, deps0 = b.tasks[0]
    b.tasks[0] = (tid0, deps0 + ("ghost-task",))
    return verify_task_graph(b.tasks)


@mutation("fab-drop-step", "fab.unreachable", kind="fabric")
def _mut_fab_drop_step(b: Bundle):
    from .fabric import verify_collective
    steps = list(b.steps["all_gather"])
    steps.pop()
    return verify_collective("all_gather", steps, b.topo.n_chips)


@mutation("fab-chain-broken", "fab.chain-broken", kind="fabric")
def _mut_fab_chain(b: Bundle):
    from .fabric import verify_collective
    kind = ("reduce_scatter" if "reduce_scatter" in b.steps
            else "all_reduce")
    steps = [s for s in b.steps[kind] if not s.reduce or s.step != 0]
    return verify_collective(kind, steps, b.topo.n_chips)


@mutation("fab-drop-shard", "fab.contract", kind="fabric")
def _mut_fab_drop_shard(b: Bundle):
    from .fabric import verify_partition
    pp = b.partition
    object.__setattr__(pp, "shards", tuple(pp.shards)[:-1])
    return verify_partition(pp)


# -- graph layer ------------------------------------------------------------ #


@mutation("gra-cycle", "gra.cycle", kind="graph")
def _mut_gra_cycle(b: Bundle):
    # Rotate the last node to the front: it now consumes intermediates that
    # are only produced later.
    g = b.kgraph
    g.nodes = (g.nodes[-1],) + g.nodes[:-1]


@mutation("gra-shape-mismatch", "gra.shape", kind="graph")
def _mut_gra_shape(b: Bundle):
    g = b.kgraph
    t = g.nodes[0].produced()[0]
    spec = g.tensors[t]
    object.__setattr__(spec, "shape", tuple(s + 1 for s in spec.shape))


@mutation("gra-dtype-mismatch", "gra.dtype", kind="graph")
def _mut_gra_dtype(b: Bundle):
    g = b.kgraph
    t = g.nodes[0].produced()[0]
    object.__setattr__(g.tensors[t], "dtype", "bf16")


@mutation("gra-ghost-tensor", "gra.unknown-tensor", kind="graph")
def _mut_gra_ghost(b: Bundle):
    node = b.kgraph.nodes[0]
    (buf, _), *rest = node.inputs
    object.__setattr__(node, "inputs", ((buf, "GHOST"), *rest))


@mutation("gra-duplicate-producer", "gra.duplicate-producer", kind="graph")
def _mut_gra_dup_producer(b: Bundle):
    g = b.kgraph
    twin = copy.deepcopy(g.nodes[0])
    object.__setattr__(twin, "name", g.nodes[0].name + "_twin")
    g.nodes = g.nodes + (twin,)


@mutation("gra-node-program", "gra.node-program", kind="graph")
def _mut_gra_node_program(b: Bundle):
    # Corrupt one node's kernel program (out-of-bounds access): the prg.*
    # layer fires inside the graph sweep and surfaces as gra.node-program.
    s = b.kgraph.nodes[0].program.statements[0]
    object.__setattr__(s.rhs, "offset", tuple(o + 10_000 for o in s.rhs.offset))


@mutation("gra-over-budget", "gra.capacity", kind="graph")
def _mut_gra_over_budget(b: Bundle):
    b.locations = {t: "vmem" for t in b.locations}
    b.budget = 1


# -- serving layer ----------------------------------------------------------- #


@mutation("srv-over-admit", "srv.kv-budget", kind="serve")
def _mut_srv_over_admit(b: Bundle):
    # Pack every request into the busiest iteration's batch: the summed KV
    # footprint blows through the byte budget (and likely the batch cap).
    all_rids = [r["rid"] for r in b.trace["requests"]]
    b.trace["iterations"][0]["running"] = all_rids
    b.trace2 = {}


@mutation("srv-bucket-miss", "srv.bucket-route", kind="serve")
def _mut_srv_bucket_miss(b: Bundle):
    # Route a small prompt to the biggest bucket: a lattice miss served by
    # a wrong-shape artifact.
    req = min(b.trace["requests"], key=lambda r: r["prompt_len"])
    req["bucket"] = max(b.trace["buckets"])
    b.trace2 = {}


@mutation("srv-replay-drift", "srv.replay-drift", kind="serve")
def _mut_srv_replay_drift(b: Bundle):
    # Nudge one completion in the "frozen" twin: the replay no longer
    # reproduces the online run bit-for-bit.
    req = next(r for r in b.trace2["requests"]
               if r["completed"] is not None)
    req["completed"] += 1e-6


@mutation("srv-starve", "srv.starvation", kind="serve")
def _mut_srv_starve(b: Bundle):
    # A buggy policy never schedules the last request: wipe its admission
    # and scrub it from every iteration.
    victim = b.trace["requests"][-1]
    victim["admitted"] = victim["completed"] = None
    for itrec in b.trace["iterations"]:
        itrec["running"] = [r for r in itrec["running"]
                            if r != victim["rid"]]
        itrec["admitted"] = [r for r in itrec["admitted"]
                             if r != victim["rid"]]
    b.trace2 = {}


# -- artifact payloads ------------------------------------------------------ #


@mutation("art-missing-field", "art.schema")
def _mut_art_schema(b: Bundle):
    from .artifact import verify_artifact_dict
    del b.artifact["cost"]
    return verify_artifact_dict(b.artifact)


@mutation("art-bad-cost", "art.cost")
def _mut_art_cost(b: Bundle):
    from .artifact import verify_artifact_dict
    b.artifact["cost"] = float("inf")
    return verify_artifact_dict(b.artifact)


@mutation("art-bad-tile", "art.instr-plan")
def _mut_art_tile(b: Bundle):
    from .artifact import verify_artifact_dict
    plan = b.artifact["instrs"][0]
    plan["tile"] = [[axis, 0] for axis, _ in plan["tile"]]
    return verify_artifact_dict(b.artifact)


@mutation("art-bad-counts", "art.counts")
def _mut_art_counts(b: Bundle):
    from .artifact import verify_artifact_dict
    b.artifact["counts"] = {"copy": -3}
    return verify_artifact_dict(b.artifact)


# -- gpu target ------------------------------------------------------------- #


@mutation("gpu-smem-capacity", "sch.capacity", kind="gpu")
def _mut_gpu_smem_capacity(b: Bundle):
    # Shrink every shared-memory node below the tile working set: the
    # schedule that fit real cluster smem now claims more bytes than the
    # (corrupted) machine has — the replay must flag it, whatever the
    # staging memory is called on this target.
    g = b.schedule.graph
    for m in g.memories.values():
        if m.role == "staging":
            object.__setattr__(m, "capacity", 1024)


@mutation("gpu-wrong-lowering", "art.lowering-target", kind="gpu")
def _mut_gpu_wrong_lowering(b: Bundle):
    from .artifact import verify_artifact_dict
    # A tpu-shaped lowering config on a gpu-keyed artifact: the config an
    # artifact cache would serve if target families ever got crossed.
    b.artifact["lowering"] = {"kind": "pallas_gemm",
                              "block": b.artifact["lowering"]["block"],
                              "grid": b.artifact["lowering"]["grid"]}
    return verify_artifact_dict(b.artifact)


# --------------------------------------------------------------------------- #
# Runner
# --------------------------------------------------------------------------- #


@dataclass
class MutationResult:
    name: str
    expected: str
    caught: bool
    rules: list[str]

    def __str__(self) -> str:
        mark = "caught" if self.caught else "MISSED"
        return f"[{mark}] {self.name}: expected {self.expected}, " \
               f"got {sorted(set(self.rules)) or 'nothing'}"


_BUNDLES = {"gemm": _gemm_bundle, "gpu": _gpu_bundle,
            "fabric": _fabric_bundle,
            "graph": _graph_bundle, "serve": _serve_bundle,
            "incremental": _incremental_bundle}


def run_mutation(name: str) -> MutationResult:
    rule, kind, fn = MUTATIONS[name]
    bundle = _BUNDLES[kind]()
    diags = fn(bundle)
    if diags is None:                       # mutator corrupted in place
        diags = _verify_bundle(bundle)
    rules = [d.rule for d in diags]
    return MutationResult(name=name, expected=rule,
                          caught=rule in rules, rules=rules)


def run_all() -> list[MutationResult]:
    return [run_mutation(name) for name in MUTATIONS]


def baseline_report() -> DiagnosticReport:
    """The unmutated bundles must verify clean (no false positives)."""
    report = DiagnosticReport()
    report.extend(_verify_bundle(_gemm_bundle()))
    gb = _gpu_bundle()
    from .artifact import verify_artifact_dict
    report.extend(_verify_bundle(gb))
    report.extend(verify_artifact_dict(gb.artifact))
    fb = _fabric_bundle()
    from .fabric import verify_partition, verify_task_graph
    report.extend(verify_partition(fb.partition))
    report.extend(verify_task_graph(fb.tasks))
    report.extend(_verify_bundle(_graph_bundle()))
    report.extend(_verify_bundle(_serve_bundle()))
    report.extend(_verify_bundle(_incremental_bundle()))
    return report
