"""``repro verify`` — run the static analyzer over the tune suites.

    repro verify                          # gemm+gru+conv+fabric+graph+serve
    repro verify --suite gemm,conv        # subset
    repro verify --tuned                  # also check tuned configs (cache)
    repro verify --mutate                 # prove the rules fire (harness)
    repro verify --json report.json

Every case compiles fresh (Schedule only — the verifier is the subject
here, so it runs *after* the pipeline, not inside it) and the report lists
each diagnostic with its rule id.  Exit status: 0 iff every compile
verifies clean and — with ``--mutate`` — every corruption class is caught.
"""
from __future__ import annotations

import argparse
import json

SUITES = ("gemm", "gru", "conv", "fabric", "graph", "serve")


def _verify_suite_cases(suite: str, limit, tuned: bool, rows: list) -> int:
    from ..compile.driver import compile_selection
    from ..search.tune import build_cases, make_graph
    from . import verify_compile
    failures = 0
    graph = make_graph("tpu")
    for case in build_cases(suite, limit):
        for label, approach in _approaches(case, graph, tuned):
            art = compile_selection(case.selection, graph, approach,
                                    program=case.program)
            report = verify_compile(selection=case.selection,
                                    schedule=art.schedule,
                                    approach=art.approach)
            failures += _emit(f"{case.name}[{label}]", report, rows)
    return failures


def _approaches(case, graph, tuned: bool):
    """(label, approach) pairs for one case: greedy, plus the tuned config
    when a cache record exists."""
    yield "greedy", None
    if not tuned:
        return
    from ..search.cache import get_default_cache
    from ..search.space import ParamApproach, tuning_key
    cache = get_default_cache()
    rec = cache.lookup(tuning_key(case.program, graph, "cost"))
    if rec is not None and getattr(rec, "config", None):
        yield "tuned", ParamApproach(rec.config)


def _verify_fabric_cases(limit, rows: list) -> int:
    from ..fabric.partition import partition, partition_axes
    from ..fabric.topology import make_topology
    from . import DiagnosticReport, verify_fabric
    from ..search.tune import FABRIC_GEMM_SIZES
    failures = 0
    topo = make_topology("ring", 4)
    shapes = FABRIC_GEMM_SIZES[:limit] if limit else FABRIC_GEMM_SIZES
    for shape in shapes:
        for axis in partition_axes("gemm"):
            pp = partition("gemm", shape, axis, topo.n_chips)
            report = DiagnosticReport()
            report.extend(verify_fabric(pp, topo))
            name = "fabric_gemm_{}_{}".format("x".join(map(str, shape)), axis)
            failures += _emit(name, report, rows)
    return failures


def _verify_graph_cases(limit, rows: list) -> int:
    """The graph layer: traced kernel graphs (fused and unfused) plus their
    placement plans must verify clean under the ``gra.*`` rules."""
    from ..configs.registry import get_trace_config
    from ..graph.compile import RESIDENCY_FRAC, plan_placement
    from ..graph.fuse import fuse_epilogues
    from ..graph.trace import trace_block, trace_gru_chain
    from ..core.sysgraph import V5E_VMEM_BYTES
    from . import DiagnosticReport, verify_graph, verify_placement
    failures = 0
    cases = [("block_unfused",
              lambda: trace_block(get_trace_config("olmo-1b"), seq_len=8)),
             ("block_fused",
              lambda: fuse_epilogues(
                  trace_block(get_trace_config("olmo-1b"), seq_len=8))[0]),
             ("gru_chain", trace_gru_chain)]
    budgets = (int(V5E_VMEM_BYTES * RESIDENCY_FRAC), 4096)
    for name, build in cases[:limit] if limit else cases:
        g = build()
        report = DiagnosticReport()
        report.extend(verify_graph(g))
        for budget in budgets:
            pl = plan_placement(g, budget)
            report.extend(verify_placement(g, pl.locations, budget))
        failures += _emit(f"graph_{name}", report, rows)
    return failures


def _verify_serve_cases(limit, rows: list) -> int:
    """The serving layer: seeded online and static runs must produce
    ``srv.*``-clean traces, and the frozen replay of the online policy
    must agree with the live run to the bit."""
    from ..serve.bucket import ServingPool
    from ..serve.scheduler import (FifoOnlineScheduler, StaticBatchScheduler,
                                   make_static_scheduler)
    from ..serve.simulate import ServeParams, simulate_serving
    from ..serve.workload import generate_requests
    from . import DiagnosticReport, verify_replay, verify_serve_trace
    failures = 0
    pool = ServingPool(archs=("olmo-1b",), buckets=(4, 8), use_cache=False)
    pool.warmup()
    reqs = generate_requests(12, seed=0, rate=400.0,
                             prompt_lens=(2, 4, 6, 8), decode_lens=(1, 2, 3))
    params = ServeParams(max_batch=4, kv_budget=1 << 15)
    cases = [("online", FifoOnlineScheduler()),
             ("static", StaticBatchScheduler())]
    results = {}
    for name, sched in cases[:limit] if limit else cases:
        res = simulate_serving(reqs, pool, sched, params)
        results[name] = res
        report = DiagnosticReport()
        report.extend(verify_serve_trace(res.trace()))
        failures += _emit(f"serve_{name}", report, rows)
    if "online" in results:
        frozen = simulate_serving(
            reqs, pool, make_static_scheduler(FifoOnlineScheduler)(), params)
        report = DiagnosticReport()
        report.extend(verify_serve_trace(frozen.trace()))
        report.extend(verify_replay(frozen.trace(),
                                    results["online"].trace()))
        failures += _emit("serve_frozen_replay", report, rows)
    return failures


def _emit(name: str, report, rows: list) -> int:
    rows.append({"case": name, **report.to_dict()})
    status = "ok" if report.ok else "FAIL"
    extra = f", {len(report.warnings)} warning(s)" if report.warnings else ""
    print(f"[{status}] {name}: {len(report.errors)} error(s){extra}")
    for d in report.diagnostics:
        print(f"    {d}")
    return 0 if report.ok else 1


def _run_mutations(rows: list) -> int:
    from .mutate import baseline_report, run_all
    base = baseline_report()
    failures = _emit("mutate-baseline", base, rows)
    missed = total = 0
    for res in run_all():
        print(f"  {res}")
        rows.append({"mutation": res.name, "expected": res.expected,
                     "caught": res.caught, "rules": sorted(set(res.rules))})
        missed += not res.caught
        total += 1
    if missed:
        print(f"[FAIL] mutation harness: {missed} class(es) NOT caught")
    else:
        print(f"[ok] mutation harness: all {total} classes caught")
    return failures + missed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro verify",
        description="Static analyzer sweep: verify every tune-suite compile "
                    "(program/selection/schedule/fabric layers) and "
                    "optionally prove the rules fire via the mutation "
                    "harness.")
    ap.add_argument("--suite", default="all",
                    help=f"comma list from {SUITES} or 'all'")
    ap.add_argument("--limit", type=int, default=None,
                    help="cap the number of cases per suite")
    ap.add_argument("--tuned", action="store_true",
                    help="also verify tuned configs from the tuning cache")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="tuning cache for --tuned (default: the standard "
                         "cache location)")
    ap.add_argument("--mutate", action="store_true",
                    help="run the mutation harness as well")
    ap.add_argument("--rules", action="store_true",
                    help="print the rule table and exit")
    ap.add_argument("--json", default=None, help="write the report here")
    args = ap.parse_args(argv)

    if args.rules:
        from .diagnostics import RULES
        for rule, desc in RULES.items():
            print(f"{rule:<22} {desc}")
        return 0

    suites = SUITES if args.suite == "all" else \
        tuple(s.strip() for s in args.suite.split(","))
    bad = [s for s in suites if s not in SUITES]
    if bad:
        ap.error(f"unknown suite(s) {bad}; pick from {SUITES}")

    if args.cache:
        from ..search.cache import TuningCache, set_default_cache
        set_default_cache(TuningCache(args.cache))

    rows: list = []
    failures = 0
    for suite in suites:
        if suite == "fabric":
            failures += _verify_fabric_cases(args.limit, rows)
        elif suite == "graph":
            failures += _verify_graph_cases(args.limit, rows)
        elif suite == "serve":
            failures += _verify_serve_cases(args.limit, rows)
        else:
            failures += _verify_suite_cases(suite, args.limit, args.tuned,
                                            rows)
    if args.mutate:
        failures += _run_mutations(rows)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"schema": 1, "failures": failures, "rows": rows},
                      f, indent=2)
        print(f"# report: {args.json}")
    print(f"# {len(rows)} check(s), {failures} failure(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
